"""Mesh-sharded packed serving: per-IMCU resident shards + routed pumps.

The invariant under test everywhere: sharded serving output is BIT-exact
(assert_array_equal) against the unsharded packed/int32 paths — sharding
changes where launches run and which stream slice they read, never the
math. Runs on any device count: with one process device every shard's
executor commits to it (round-robin degenerates); CI additionally runs
this file under XLA_FLAGS=--xla_force_host_platform_device_count=4 so the
true multi-device routing is exercised on CPU.
"""
import time

import numpy as np
import pytest

from repro.columnar import Table
from repro.core import (FeatureSet, FeaturePipeline, FeaturePlan,
                        FeatureExecutor, ShardedFeatureExecutor)
from repro.core.pipeline import _PackedShardPlan
from repro.kernels.bitunpack.kernel import tpu_width
from repro.serve import FeatureService

BITS_SWEEP = (1, 2, 3, 4, 6, 8, 12, 16)


def _column_data(rng, bits, n):
    """Integer column whose dictionary needs exactly ``bits`` bits."""
    k = 2 if bits == 1 else (1 << (bits - 1)) + 1
    base = np.arange(k)
    return np.concatenate([base, rng.integers(0, k, n - k)])


def _mixed_table(n=3000, imcu_rows=700, seed=0):
    rng = np.random.default_rng(seed)
    t = Table.from_data({
        "age": rng.integers(18, 80, n),
        "state": np.array(["CA", "OR", "WA", "NY"])[rng.integers(0, 4, n)],
        "income": rng.integers(20, 200, n) * 1000,
    }, imcu_rows=imcu_rows)
    fs = (FeatureSet().add("age", "zscore").add("state", "onehot")
          .add("income", "minmax"))
    return t, fs


# -- packed shard plans (the host-side half) -----------------------------------------
def test_packed_imcu_shards_structure_and_seam_repack():
    """Word-aligned boundaries slice zero-copy; unaligned seams repack only
    the shard's own rows; the fused super-table stays shared."""
    rng = np.random.default_rng(1)
    t = Table.from_data({"a": rng.integers(0, 100, 1024),   # db=8, s=4
                         "b": rng.integers(0, 3, 1024)},    # db=2, s=16
                        imcu_rows=256)                      # 256 % 16 == 0
    fs = FeatureSet().add("a", "zscore").add("b", "onehot")
    plan = FeaturePlan(t, fs, packed=True)
    shards = plan.imcu_shards()
    assert len(shards) == 4 and all(isinstance(s, _PackedShardPlan)
                                    for s in shards)
    # aligned boundary -> shard words are views into the parent stream
    w = shards[1]._shard_words(0)
    assert w.base is plan.packed_words[0] or \
        w.base is plan.packed_words[0].base
    assert plan.stats["words_repacked"] == 0       # no seams at 256 rows
    assert shards[0].fused_tables() is plan.fused_tables()
    # local host_codes equal the parent's global window
    np.testing.assert_array_equal(
        shards[2].host_codes(np.arange(0, 256)),
        plan.host_codes(np.arange(512, 768)))
    # unaligned IMCU rows (700 % 16 != 0) force a seam repack for db=2 only
    t2, fs2 = _mixed_table()
    plan2 = FeaturePlan(t2, fs2, packed=True)
    sh2 = plan2.imcu_shards()
    sh2[1].packed_words                            # build the slices
    assert sh2[1].stats["words_repacked"] >= 1
    np.testing.assert_array_equal(
        sh2[1].host_codes(np.arange(0, 700)),
        plan2.host_codes(np.arange(700, 1400)))


def test_shard_stats_attributed_and_rolled_up():
    """Each shard's counters are its own AND every delta lands in the plan
    total — the unattributable-shared-dict fix."""
    t, fs = _mixed_table(n=2048, imcu_rows=1024)
    plan = FeaturePlan(t, fs, packed=True)
    base_puts = plan.stats["words_put"]
    shx = ShardedFeatureExecutor(plan)
    per_shard = plan.stats["per_shard"]
    assert [s.stats for s in shx.shards] == per_shard
    assert all(s["words_put"] == 1 for s in per_shard)   # one put each
    assert plan.stats["words_put"] == base_puts + 2      # rolled up
    # int32 shards get attributed stats too
    plan_i = FeaturePlan(t, fs)
    shards_i = plan_i.imcu_shards()
    assert all(dict(s.stats)["tables_put"] == 0 for s in shards_i)


# -- routed executor bit-exactness ---------------------------------------------------
@pytest.mark.parametrize("use_kernel", [False, True])
def test_sharded_executor_bit_exact_across_bits(use_kernel):
    """Sharded serve == unsharded for aligned ranges AND arbitrary rows,
    every storage width class 1-16 bits, rows straddling shard boundaries."""
    rng = np.random.default_rng(7)
    n = 33024                  # bits=16 needs cardinality 2**15 + 1 <= n
    data = {f"c{b}": _column_data(rng, b, n) for b in BITS_SWEEP}
    table = Table.from_data(data, imcu_rows=8256)       # 4 shards, 8256%32=0
    fs = FeatureSet()
    for b in BITS_SWEEP:
        fs = fs.add(f"c{b}", "zscore")
    plan_p = FeaturePlan(table, fs, packed=True)
    assert [tpu_width(b) for b in BITS_SWEEP] == plan_p.device_bits
    ex_i = FeatureExecutor(FeaturePlan(table, fs))
    shx = ShardedFeatureExecutor(plan_p, use_kernel=use_kernel)
    assert shx.n_shards == 4
    # aligned ranges: inside one shard, and straddling shard boundaries
    for start, m in ((0, 128), (8256 - 64, 128), (8256 * 2 - 32, 96)):
        idx = np.arange(start, start + m)
        np.testing.assert_array_equal(np.asarray(shx.batch(idx)),
                                      np.asarray(ex_i.batch(idx)))
    # arbitrary rows spanning every shard, biased onto boundary straddles
    bounds = np.array([8256, 8256 * 2, 8256 * 3])
    rows = np.concatenate([bounds - 1, bounds, bounds + 1,
                           rng.integers(0, n, 300)])
    np.testing.assert_array_equal(np.asarray(shx.batch(rows)),
                                  np.asarray(ex_i.batch(rows)))


def test_sharded_executor_routing_and_error_contract():
    t, fs = _mixed_table()
    shx = ShardedFeatureExecutor(FeaturePlan(t, fs, packed=True))
    assert shx.n_shards == 5
    # whole-request fast path: no dest index materialized
    [(s, local, dest)] = shx.route(np.arange(1400, 1450))
    assert s == 2 and dest is None and local[0] == 0
    # split request: dests reassemble the original order
    pieces = shx.route(np.array([2999, 0, 700]))
    assert [p[0] for p in pieces] == [0, 1, 4]
    with pytest.raises(IndexError):
        shx.batch(np.array([3000]))
    assert np.asarray(shx.batch(np.array([], np.int64))).shape == \
        (0, shx.plan.out_dim)
    with pytest.raises(ValueError):                # int32 plans don't shard
        ShardedFeatureExecutor(FeaturePlan(t, fs))


def test_sharded_executor_serves_refresh_appends_in_last_shard():
    """Streaming inserts extend the open-ended last shard: appends past the
    compile-time bounds (and past the pad32 capacity) serve bit-exact."""
    rng = np.random.default_rng(3)
    t, fs = _mixed_table(n=2048, imcu_rows=512)
    plan_p = FeaturePlan(t, fs, packed=True)
    plan_i = FeaturePlan(t, fs)
    shx = ShardedFeatureExecutor(plan_p)
    ex_i = FeatureExecutor(plan_i)
    np.asarray(shx.batch(np.arange(2048 - 64, 2048)))   # put at old capacity
    new = {"age": t["age"].dictionary.add_rows(rng.integers(18, 80, 40)),
           "state": t["state"].dictionary.add_rows(
               np.array(["CA", "NY"] * 20)),
           "income": t["income"].dictionary.add_rows(
               rng.integers(20, 200, 40) * 1000)}
    plan_p.refresh(new)
    plan_i.refresh(new)
    assert shx.shards[-1].n_rows == 512 + 40            # open-ended tail
    rows = np.concatenate([np.arange(2040, 2088),       # spans old capacity
                           rng.integers(0, 2088, 200)])
    np.testing.assert_array_equal(np.asarray(shx.batch(rows)),
                                  np.asarray(ex_i.batch(rows)))


# -- sharded FeatureService ----------------------------------------------------------
@pytest.mark.parametrize("use_kernel", [False, True])
def test_sharded_service_matches_pipeline(use_kernel):
    t, fs = _mixed_table()
    pipe = FeaturePipeline(t, fs)
    rng = np.random.default_rng(5)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        use_kernel=use_kernel, buckets=(64, 256)) as svc:
        assert svc.n_shards == 5
        reqs = [np.arange(0, 256),                 # one shard, aligned
                np.arange(672, 736),               # straddles shards 0/1
                rng.integers(0, 3000, 400),        # scatter over all shards
                np.array([699, 700, 1399, 1400, 2099, 2100]),  # boundaries
                np.arange(2980, 3000)]             # tail of last shard
        tickets = [svc.submit(r) for r in reqs]
        for r, tk in zip(reqs, tickets):
            np.testing.assert_array_equal(svc.result(tk),
                                          np.asarray(pipe.batch(r)))
        assert svc.stats["split_requests"] >= 3
        # per-shard launch attribution sums to the totals
        assert sum(svc.stats["shard_launches"]) == svc.stats["launches"] > 0
        assert sum(svc.stats["shard_bytes_h2d"]) == svc.stats["bytes_h2d"]
        assert sum(1 for x in svc.stats["shard_launches"] if x) >= 4


def test_sharded_service_serves_refresh_appends():
    rng = np.random.default_rng(6)
    t, fs = _mixed_table(n=2000, imcu_rows=800)
    pipe = FeaturePipeline(t, fs)
    plan_p = FeaturePlan(t, fs, packed=True)
    with FeatureService(plan_p, sharded=True, buckets=(64,)) as svc:
        svc.result(svc.submit(np.arange(64)))      # compile pre-refresh
        new = {"age": t["age"].dictionary.add_rows(np.array([150, 151])),
               "state": t["state"].dictionary.add_rows(
                   np.array(["CA", "OR"])),
               "income": t["income"].dictionary.add_rows(
                   np.array([40000, 60000]))}
        plan_p.refresh(new)
        pipe.plan.refresh(new)
        mixed = np.array([0, 799, 800, 1999, 2000, 2001])  # shards + tail
        np.testing.assert_array_equal(svc.result(svc.submit(mixed)),
                                      np.asarray(pipe.batch(mixed)))


def test_sharded_service_concurrent_shard_pumps():
    """Whole-shard requests land on their own pumps; drain joins them all
    and every per-shard window respects prefetch."""
    t, fs = _mixed_table(n=4096, imcu_rows=1024)
    pipe = FeaturePipeline(t, fs)
    rng = np.random.default_rng(8)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        prefetch=2, buckets=(64,)) as svc:
        reqs = [np.arange(s, s + 64)
                for s in rng.integers(0, 4096 - 64, 40)]
        tickets = [svc.submit(r) for r in reqs]
        out = svc.drain()
        assert set(out) == set(tickets)
        for r, tk in zip(reqs, tickets):
            np.testing.assert_array_equal(out[tk], np.asarray(pipe.batch(r)))
        # aggregate in-flight is bounded by prefetch per shard
        assert svc.stats["max_inflight"] <= 2 * svc.n_shards


# -- latency-aware linger ------------------------------------------------------------
def test_linger_coalesces_partial_groups():
    """With a generous linger the pump holds partial groups open until the
    burst arrives — the whole burst serves in ONE coalesced launch without
    pause()/resume() choreography."""
    rng = np.random.default_rng(9)
    t = Table.from_data({"a": rng.integers(0, 100, 4096)})
    fs = FeatureSet().add("a", "zscore")
    pipe = FeaturePipeline(t, fs)
    with FeatureService(FeaturePlan(t, fs, packed=True), buckets=(128,),
                        coalesce=4, linger_us=2_000_000) as svc:
        starts = [0, 512, 1024, 2048]
        tickets = [svc.submit(np.arange(s, s + 128)) for s in starts]
        out = [svc.result(tk) for tk in tickets]
        assert svc.stats["launches"] == 1          # lingered into one group
        for s, got in zip(starts, out):
            np.testing.assert_array_equal(
                got, np.asarray(pipe.batch(np.arange(s, s + 128))))


def test_linger_latency_is_bounded():
    """A lone request must complete within (roughly) the linger deadline —
    lingering trades BOUNDED latency for coalescing, it never starves."""
    rng = np.random.default_rng(10)
    t = Table.from_data({"a": rng.integers(0, 100, 1024)})
    fs = FeatureSet().add("a", "zscore")
    with FeatureService(FeaturePlan(t, fs, packed=True), buckets=(64,),
                        coalesce=4, linger_us=50_000) as svc:
        t0 = time.perf_counter()
        got = svc.result(svc.submit(np.arange(64)))
        wall = time.perf_counter() - t0
        assert got.shape == (64, 1)
        # deadline 50ms; generous ceiling absorbs compile + scheduler noise
        assert wall < 20.0
        assert svc.stats["launches"] == 1
    # a full group launches immediately even with linger configured
    with FeatureService(FeaturePlan(t, fs, packed=True), buckets=(64,),
                        coalesce=2, linger_us=10_000_000) as svc:
        svc.pause()
        a = svc.submit(np.arange(64))
        b = svc.submit(np.arange(64, 128))
        svc.resume()
        t0 = time.perf_counter()
        svc.result(a), svc.result(b)
        assert time.perf_counter() - t0 < 5.0      # did not sit out 10s
        assert svc.stats["launches"] == 1


def test_linger_rejects_negative():
    t = Table.from_data({"a": np.arange(64)})
    with pytest.raises(ValueError):
        FeatureService(FeaturePlan(t, FeatureSet().add("a", "zscore"),
                                   packed=True), linger_us=-1)


def test_append_resyncs_only_last_shard_stream():
    """A streaming append rewrites the tail — interior shards' resident
    streams must NOT be re-put (their bytes are untouched), and executors
    sharing a device share ONE set of placed tables."""
    rng = np.random.default_rng(31)
    t, fs = _mixed_table(n=2048, imcu_rows=512)
    plan_p = FeaturePlan(t, fs, packed=True)
    plan_i = FeaturePlan(t, fs)
    shx = ShardedFeatureExecutor(plan_p)
    ex_i = FeatureExecutor(plan_i)
    all_rows = np.arange(0, 2048, 7)
    np.asarray(shx.batch(all_rows))                 # every shard puts once
    puts0 = [s.stats["words_put"] for s in shx.shards]
    new = {"age": t["age"].dictionary.add_rows(np.array([77])),
           "state": t["state"].dictionary.add_rows(np.array(["CA"])),
           "income": t["income"].dictionary.add_rows(np.array([50000]))}
    plan_p.refresh(new)
    plan_i.refresh(new)
    rows = np.concatenate([all_rows, [2048]])       # touch every shard again
    np.testing.assert_array_equal(np.asarray(shx.batch(rows)),
                                  np.asarray(ex_i.batch(rows)))
    puts1 = [s.stats["words_put"] for s in shx.shards]
    assert puts1[-1] == puts0[-1] + 1               # tail shard re-put
    assert puts1[:-1] == puts0[:-1]                 # interior shards did NOT
    # executors on one device share placed tables (1 device in tier-1 runs)
    import jax
    if len(jax.devices()) == 1:
        assert shx.executors[0]._tcache is shx.executors[1]._tcache


def test_serve_mesh_and_devices_rules():
    import jax
    from repro.distributed.sharding import serve_mesh, serve_devices
    mesh = serve_mesh()
    assert mesh.axis_names == ("shard",)
    assert mesh.shape["shard"] == len(jax.devices())
    devs = serve_devices(5)
    all_devs = jax.devices()
    assert len(devs) == 5                         # round-robin wraps
    assert all(d is all_devs[i % len(all_devs)] for i, d in enumerate(devs))
    with pytest.raises(ValueError):
        serve_devices(0)


# -- adaptive shard management: concurrency / chaos ----------------------------------
def test_chaos_clients_race_live_rebalance():
    """submit/poll/result from client threads racing live replica flips and
    tail splits never deadlock, never drop a ticket, and every result stays
    bit-exact (= request order preserved: rows come back in request
    positions). Mutations here never refresh, so feature values are
    invariant and every interleaving has one right answer."""
    import threading

    t, fs = _mixed_table(n=8192, imcu_rows=2048)
    pipe = FeaturePipeline(t, fs)
    ref = {}                                   # precomputed per-client refs
    stop = threading.Event()
    errors: list = []
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64, 256), coalesce=4) as svc:

        def client(seed):
            rng = np.random.default_rng(seed)
            try:
                served = 0
                while not stop.is_set() or served == 0:
                    rows = rng.integers(0, 8192, int(rng.integers(8, 300)))
                    key = (seed, served % 7)
                    if key not in ref:
                        ref[key] = np.asarray(pipe.batch(rows))
                        ref_rows[key] = rows
                    rows = ref_rows[key]
                    tk = svc.submit(rows)
                    if served % 3 == 0:
                        while not svc.poll(tk):
                            time.sleep(0)
                    np.testing.assert_array_equal(svc.result(tk), ref[key])
                    served += 1
            except Exception as e:             # pragma: no cover - failure
                errors.append(e)

        ref_rows: dict = {}
        threads = [threading.Thread(target=client, args=(s,), daemon=True)
                   for s in range(3)]
        for th in threads:
            th.start()
        rng = np.random.default_rng(99)
        cuts = iter((7168, 7680, 7936))
        for i in range(9):                     # live shard-set churn
            kind = i % 3
            if kind == 0:
                svc.add_replica(int(rng.integers(0, svc.n_shards)))
            elif kind == 1:
                cut = next(cuts, None)
                if cut is not None:
                    svc.split_tail(cut)
            else:
                cands = [s for s in range(svc.n_shards)
                         if svc._sharded_ex.replicas[s]]
                if cands:
                    svc.drop_replica(int(rng.choice(cands)))
            time.sleep(0.02)
        stop.set()
        for th in threads:
            th.join(timeout=30.0)
        assert not any(th.is_alive() for th in threads), "client deadlocked"
        assert not errors, errors
        assert svc.n_shards >= 7               # the splits actually landed
        leftovers = svc.drain()                # no orphaned tickets remain
        assert sum(svc.stats["shard_launches"]) == svc.stats["launches"]
        assert not svc._chunks_total and not leftovers


def test_drain_during_migration_force_flushes():
    """drain() while a split lands mid-lingering must flush the re-routed
    chunks promptly (no waiting out the linger deadline) and lose
    nothing."""
    t, fs = _mixed_table(n=4096, imcu_rows=1024)
    pipe = FeaturePipeline(t, fs)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64,), coalesce=8,
                        linger_us=30_000_000) as svc:
        # partial groups: they would linger 30s without the flush
        reqs = [np.arange(3072, 3136), np.arange(3800, 3864),
                np.arange(4000, 4064)]
        tickets = [svc.submit(r) for r in reqs]
        svc.split_tail(3840)                   # re-routes (and splits) them
        t0 = time.perf_counter()
        out = svc.drain()
        assert time.perf_counter() - t0 < 10.0
        assert set(out) == set(tickets)
        for r, tk in zip(reqs, tickets):
            np.testing.assert_array_equal(out[tk], np.asarray(pipe.batch(r)))
        # the straddle split re-states the submit-time accounting: the
        # [3800,3864) chunk became 40+24-row pieces (64 fresh pad rows) and
        # its tail piece is now a shard-local aligned range
        assert svc.stats["padded_rows"] == 64
        assert svc.stats["packed_ranges"] == 3


def test_pause_rebalance_resume_bit_exact():
    """pause -> rebalance() (monitor splits the over-budget tail AND
    replicates the heated shard) -> resume: chunks queued across the swap —
    including ones straddling the new cut — serve bit-exact."""
    t, fs = _mixed_table(n=5000, imcu_rows=2048)   # tail IMCU: 904 rows
    pipe = FeaturePipeline(t, fs)
    rng = np.random.default_rng(12)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64,), coalesce=2, row_budget=512,
                        hot_factor=2.0, max_replicas=2) as svc:
        for _ in range(6):                     # heat shard 0's request rate
            svc.result(svc.submit(rng.integers(0, 2048, 64)))
        svc.pause()
        reqs = [np.arange(4544, 4672),         # straddles the coming cut
                rng.integers(0, 5000, 200),
                np.arange(4096, 5000)]         # the whole old tail
        tickets = [svc.submit(r) for r in reqs]
        actions = svc.rebalance()
        assert actions["split"] and actions["split"][0][2] == 4608
        assert actions["replicated"] and actions["replicated"][0][0] == 0
        svc.resume()
        for r, tk in zip(reqs, tickets):
            np.testing.assert_array_equal(svc.result(tk),
                                          np.asarray(pipe.batch(r)))
        assert svc.stats["shard_splits"] == 1
        assert svc.stats["replicas_added"] == 1


def test_auto_monitor_replicates_and_splits():
    """The pump-driven monitor (rebalance_every) detects hot-key skew from
    the per-shard stats deltas and replicates the hot shard; the row budget
    splits the oversized tail — all mid-traffic, all bit-exact."""
    t, fs = _mixed_table(n=5000, imcu_rows=2048)
    pipe = FeaturePipeline(t, fs)
    rng = np.random.default_rng(13)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64,), coalesce=2, rebalance_every=4,
                        row_budget=512, hot_factor=2.0,
                        max_replicas=2) as svc:
        reqs = [rng.integers(0, 2048, 64) for _ in range(30)]   # hot shard 0
        tickets = [svc.submit(r) for r in reqs]
        out = svc.drain()
        for r, tk in zip(reqs, tickets):
            np.testing.assert_array_equal(out[tk], np.asarray(pipe.batch(r)))
        assert svc.stats["rebalances"] >= 1
        assert svc.stats["replicas_added"] >= 1        # skew detected
        assert svc._sharded_ex.replicas[0]             # ... on shard 0
        assert svc.stats["shard_splits"] >= 1          # tail over budget
        mixed = np.concatenate([np.arange(4544, 4672),
                                rng.integers(0, 5000, 300)])
        np.testing.assert_array_equal(svc.result(svc.submit(mixed)),
                                      np.asarray(pipe.batch(mixed)))


def test_auto_monitor_default_hot_factor_reachable():
    """The hot test compares against the mean of the OTHER shards, so the
    DEFAULT hot_factor (4.0) triggers on a 4-shard mesh under pure skew —
    with the all-shard mean it could never exceed n_shards x itself."""
    t, fs = _mixed_table(n=4096, imcu_rows=1024)
    rng = np.random.default_rng(14)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64,), coalesce=2, rebalance_every=4,
                        max_replicas=1) as svc:
        assert svc.n_shards == 4 and svc.hot_factor == 4.0
        for _ in range(24):                    # 100% of traffic on shard 0
            svc.submit(rng.integers(0, 1024, 64))
        svc.drain()
        assert svc.stats["replicas_added"] >= 1
        assert svc._sharded_ex.replicas[0]


def test_manual_add_replica_respects_configured_cap():
    """An explicitly configured max_replicas bounds the public mutator too,
    not just the auto policy."""
    t, fs = _mixed_table(n=2048, imcu_rows=1024)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        max_replicas=1) as svc:
        svc.add_replica(0)
        with pytest.raises(ValueError):
            svc.add_replica(0)
    # unset cap: explicit operator calls are unbounded (single-device OK)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True) as svc:
        svc.add_replica(1)
        svc.add_replica(1)
        assert len(svc._sharded_ex.replicas[1]) == 2


def test_split_tail_default_cut_clamps_on_short_tail():
    """A no-arg split_tail() on a sub-32-row tail clamps its default cut to
    the tail end (proactive close) instead of raising."""
    t, fs = _mixed_table(n=2048 + 20, imcu_rows=1024)   # 20-row tail IMCU
    plan_p = FeaturePlan(t, fs, packed=True)
    sx = ShardedFeatureExecutor(plan_p)
    assert sx.tail_rows() == 20
    new = sx.split_tail()                      # default cut: clamped to stop
    assert sx.shards[new].n_rows == 0
    rows = np.arange(2040, 2068)
    np.testing.assert_array_equal(
        np.asarray(sx.batch(rows)),
        np.asarray(FeatureExecutor(FeaturePlan(t, fs)).batch(rows)))


def test_adaptive_args_validation():
    t, fs = _mixed_table(n=1400, imcu_rows=700)
    plan_i = FeaturePlan(t, fs)
    with pytest.raises(ValueError):            # adaptive needs mesh mode
        FeatureService(plan_i, rebalance_every=4)
    with pytest.raises(ValueError):
        FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                       row_budget=16)          # below one alignment word
    with FeatureService(plan_i) as svc:        # unsharded: admin is guarded
        with pytest.raises(RuntimeError):
            svc.add_replica(0)
        with pytest.raises(RuntimeError):
            svc.split_tail()
        assert svc.rebalance() == {"split": [], "replicated": [],
                                   "dropped": [],
                                   "failover_replicated": [],
                                   "rebuilt": [], "demoted": [],
                                   "promoted": []}  # no-ops


def test_sharded_service_serves_widened_plan_after_refresh():
    """A refresh that GROWS a dictionary (onehot widens -> out_dim grows)
    must keep the pump serving multi-chunk requests — retire buffers size
    off the plan's CURRENT width, not a construction-time snapshot."""
    rng = np.random.default_rng(30)
    t, fs = _mixed_table(n=2048, imcu_rows=512)
    pipe = FeaturePipeline(t, fs)
    plan_p = FeaturePlan(t, fs, packed=True)
    with FeatureService(plan_p, sharded=True, buckets=(64,)) as svc:
        svc.result(svc.submit(np.arange(64)))        # serve pre-refresh
        new = {"age": t["age"].dictionary.add_rows(np.array([150])),
               "state": t["state"].dictionary.add_rows(np.array(["TX"])),
               "income": t["income"].dictionary.add_rows(np.array([12345]))}
        plan_p.refresh(new)
        pipe.plan.refresh(new)
        assert plan_p.out_dim == pipe.plan.out_dim > 6   # onehot widened
        rows = rng.integers(0, plan_p.n_rows, 400)       # multi-chunk, split
        np.testing.assert_array_equal(svc.result(svc.submit(rows)),
                                      np.asarray(pipe.batch(rows)))
