"""ServeEngine slot semantics: ghost slots, per-request stops, truncation.

The engine serves a FIXED (batch_size, max_len) slot array whatever the
real request count — so the invariants worth locking down are the edge
behaviors of that padding: ghost (empty) slots must be bit-invisible to
real requests, per-slot stop conditions (``max_new_tokens`` / ``eos_id``)
must act per slot without perturbing neighbors, and the ``max_len``
ceiling must truncate deterministically. Plus the stats-accounting fix:
``throughput_stats`` stays JSON-safe at ``wall_s == 0``.
"""
import json

import numpy as np
import jax
import pytest

from repro.configs import get_config, reduced
from repro.models import lm
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def engine_setup():
    cfg = reduced(get_config("glm4-9b"))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompt(cfg, n=6, seed=0):
    return np.random.default_rng(seed).integers(
        0, cfg.vocab, n).astype(np.int32)


def _greedy(cfg, params, prompt, max_new, *, batch_size, max_len=24):
    eng = ServeEngine(cfg, params, batch_size=batch_size, max_len=max_len)
    return eng.run_batch([Request(prompt=prompt.copy(),
                                  max_new_tokens=max_new)])[0].out_tokens


# -- ghost slots ---------------------------------------------------------------------
def test_ghost_slots_do_not_perturb_real_outputs(engine_setup):
    """A partially-filled batch zero-pads the unused slots; the real
    request's greedy decode must be bit-identical to a batch_size=1 run —
    ghost slots decode garbage into themselves, never into neighbors."""
    cfg, params = engine_setup
    p = _prompt(cfg)
    want = _greedy(cfg, params, p, 6, batch_size=1)
    for b in (2, 4):
        got = _greedy(cfg, params, p, 6, batch_size=b)
        assert got == want, f"ghost slots leaked at batch_size={b}"


def test_two_real_slots_match_their_solo_runs(engine_setup):
    cfg, params = engine_setup
    pa, pb = _prompt(cfg, seed=1), _prompt(cfg, seed=2)
    want_a = _greedy(cfg, params, pa, 5, batch_size=1)
    want_b = _greedy(cfg, params, pb, 5, batch_size=1)
    eng = ServeEngine(cfg, params, batch_size=4, max_len=24)
    ra, rb = eng.run_batch([Request(prompt=pa.copy(), max_new_tokens=5),
                            Request(prompt=pb.copy(), max_new_tokens=5)])
    assert ra.out_tokens == want_a
    assert rb.out_tokens == want_b


# -- per-request stop conditions -----------------------------------------------------
def test_per_request_max_new_tokens(engine_setup):
    """Mixed budgets in one batch: the short request stops at ITS budget
    (a prefix of the long request's stream for identical prompts), the
    long one keeps decoding to its own."""
    cfg, params = engine_setup
    p = _prompt(cfg, seed=3)
    eng = ServeEngine(cfg, params, batch_size=4, max_len=24)
    short, long = eng.run_batch(
        [Request(prompt=p.copy(), max_new_tokens=2),
         Request(prompt=p.copy(), max_new_tokens=6)])
    assert len(short.out_tokens) == 2
    assert len(long.out_tokens) == 6
    assert short.out_tokens == long.out_tokens[:2]


def test_eos_stops_one_slot_not_its_neighbor(engine_setup):
    cfg, params = engine_setup
    p = _prompt(cfg, seed=4)
    want = _greedy(cfg, params, p, 6, batch_size=4)
    eos = want[0]
    eng = ServeEngine(cfg, params, batch_size=4, max_len=24)
    stopped, full = eng.run_batch(
        [Request(prompt=p.copy(), max_new_tokens=6, eos_id=eos),
         Request(prompt=p.copy(), max_new_tokens=6)])
    # the eos slot emits exactly the stop token, the other decodes on
    # unperturbed to its full budget
    assert stopped.out_tokens == [eos]
    assert full.out_tokens == want


def test_all_slots_eos_ends_batch_early(engine_setup):
    cfg, params = engine_setup
    p = _prompt(cfg, seed=5)
    eos = _greedy(cfg, params, p, 1, batch_size=1)[0]
    eng = ServeEngine(cfg, params, batch_size=2, max_len=24)
    done = eng.run_batch(
        [Request(prompt=p.copy(), max_new_tokens=8, eos_id=eos)
         for _ in range(2)])
    for r in done:
        assert r.out_tokens == [eos]


# -- max_len truncation --------------------------------------------------------------
def test_max_len_truncates_decode(engine_setup):
    """The slot array is (B, max_len): decode stops once the write head
    hits the ceiling, yielding exactly max_len - plen + 1 new tokens (the
    prefill's first sample lands before the position check)."""
    cfg, params = engine_setup
    plen, max_len = 6, 10
    p = _prompt(cfg, n=plen, seed=6)
    eng = ServeEngine(cfg, params, batch_size=1, max_len=max_len)
    r = eng.run_batch([Request(prompt=p, max_new_tokens=64)])[0]
    assert len(r.out_tokens) == max_len - plen + 1
    # the truncated stream is a prefix of a roomier engine's
    roomy = _greedy(cfg, params, p, 64, batch_size=1, max_len=24)
    assert r.out_tokens == roomy[:len(r.out_tokens)]


# -- input validation ----------------------------------------------------------------
def test_engine_rejects_bad_batches(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, batch_size=2, max_len=16)
    with pytest.raises(ValueError):
        eng.run_batch([Request(prompt=_prompt(cfg)) for _ in range(3)])
    with pytest.raises(ValueError):
        eng.run_batch([Request(prompt=_prompt(cfg, n=4)),
                       Request(prompt=_prompt(cfg, n=6))])


# -- stats accounting ----------------------------------------------------------------
def test_engine_throughput_stats_json_safe(engine_setup):
    """Regression: wall_s == 0 used to return tok_per_s = inf, which
    json.dump emits as the non-standard ``Infinity`` token."""
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, batch_size=1, max_len=16)
    done = eng.run_batch([Request(prompt=_prompt(cfg),
                                  max_new_tokens=4)])
    for wall in (0.0, -0.5):
        st = eng.throughput_stats(done, wall)
        assert st["wall_s_invalid"] is True
        assert st["tok_per_s"] == 0.0
        json.dumps(st, allow_nan=False)
    ok = eng.throughput_stats(done, 2.0)
    assert ok["wall_s_invalid"] is False
    assert ok["tok_per_s"] == pytest.approx(ok["new_tokens"] / 2.0)
    assert ok["new_tokens"] == 4
