"""Tests for the paper's core contribution: ADVs + featurization + feedback."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.columnar import Dictionary, Table
from repro.columnar import featurize as F
from repro.core import AugmentedDictionary, FeatureSet, FeaturePipeline
from repro.core import feedback


def _age_dict(seed=0, n=500):
    rng = np.random.default_rng(seed)
    ages = rng.integers(8, 92, size=n)
    return Dictionary.from_data(ages)


# -- featurization catalog vs direct row-space computation ----------------------
def test_float_adv_matches_rowspace():
    d, codes = _age_dict()
    aug = AugmentedDictionary(d)
    aug.add("age_fp", "float")
    out = aug.featurize("age_fp", codes)[:, 0]
    np.testing.assert_array_equal(out, d.decode(codes).astype(np.float32))


def test_minmax_zscore_match_rowspace():
    d, codes = _age_dict()
    aug = AugmentedDictionary(d)
    aug.add("mm", "minmax")
    aug.add("z", "zscore")
    vals = d.decode(codes).astype(np.float64)
    mm = (vals - vals.min()) / (vals.max() - vals.min())
    np.testing.assert_allclose(aug.featurize("mm", codes)[:, 0], mm, rtol=1e-5)
    z = (vals - vals.mean()) / vals.std()
    np.testing.assert_allclose(aug.featurize("z", codes)[:, 0], z, rtol=1e-4)


def test_bucketize_decade_paper_table5():
    # Table 5: Age 55 -> decade bucket 5.0; 42 -> 4.0; 8 -> 0.0; 17 -> 1.0
    d, codes = Dictionary.from_data(np.array([55, 42, 8, 17]))
    aug = AugmentedDictionary(d)
    aug.add("decade", "bucketize", boundaries=np.arange(10, 100, 10))
    out = aug.featurize("decade", codes)[:, 0]
    np.testing.assert_array_equal(out, [5.0, 4.0, 0.0, 1.0])


def test_bucketize_categorical_paper_table4():
    # Table 4: states -> census region buckets.
    region = {"California": 3.0, "Connecticut": 0.0, "Oregon": 3.0,
              "Virginia": 2.0}
    division = {"California": 9.0, "Connecticut": 0.0, "Oregon": 8.0,
                "Virginia": 4.0}
    data = np.array(["California", "Connecticut", "Oregon", "Virginia",
                     "Oregon"])
    d, codes = Dictionary.from_data(data)
    aug = AugmentedDictionary(d)
    aug.add("region", "bucketize_cat", mapping=region)
    aug.add("division", "bucketize_cat", mapping=division)
    np.testing.assert_array_equal(aug.featurize("region", codes)[:, 0],
                                  [3.0, 0.0, 3.0, 2.0, 3.0])
    np.testing.assert_array_equal(aug.featurize("division", codes)[:, 0],
                                  [9.0, 0.0, 8.0, 4.0, 8.0])


def test_onehot_adv_gather_equals_materialized():
    d, codes = Dictionary.from_data(np.array([3, 1, 2, 3, 1]))
    aug = AugmentedDictionary(d)
    aug.add("oh", "onehot")
    gathered = aug.featurize("oh", codes)
    np.testing.assert_array_equal(gathered,
                                  F.onehot_rows(codes, d.cardinality))


def test_quantile_and_hash_buckets():
    d, codes = _age_dict(n=2000)
    aug = AugmentedDictionary(d)
    aug.add("q4", "quantile", q=4)
    q = aug.featurize("q4", codes)[:, 0]
    assert set(np.unique(q)) <= {0.0, 1.0, 2.0, 3.0}
    # roughly balanced buckets
    _, counts = np.unique(q, return_counts=True)
    assert counts.min() > 0.15 * 2000
    aug.add("h8", "hash_bucket", n_buckets=8)
    h = aug.featurize("h8", codes)[:, 0]
    assert set(np.unique(h)) <= set(float(i) for i in range(8))


def test_binarize_and_log():
    d, codes = _age_dict()
    aug = AugmentedDictionary(d)
    aug.add("adult", "binarize", threshold=17.5)
    vals = d.decode(codes)
    np.testing.assert_array_equal(aug.featurize("adult", codes)[:, 0],
                                  (vals > 17.5).astype(np.float32))
    aug.add("lg", "log")
    np.testing.assert_allclose(aug.featurize("lg", codes)[:, 0],
                               np.log1p(vals.astype(np.float32)), rtol=1e-6)


@given(st.integers(0, 10_000), st.integers(2, 40))
@settings(max_examples=30, deadline=None)
def test_adv_equals_recompute_property(seed, card):
    """Paper's invariant: gather-through-ADV == recompute-from-raw, always."""
    rng = np.random.default_rng(seed)
    data = rng.integers(0, card, size=300)
    d, codes = Dictionary.from_data(data)
    aug = AugmentedDictionary(d)
    aug.add("z", "zscore")
    fast = aug.featurize("z", codes)
    slow = aug.featurize_recompute("z", codes)
    np.testing.assert_allclose(fast, slow, rtol=1e-5, atol=1e-6)


def test_adv_maintenance_after_insert():
    d, codes = Dictionary.from_data(np.array([1.0, 2.0, 4.0]))
    aug = AugmentedDictionary(d)
    aug.add("mm", "minmax")
    d.add_rows(np.array([8.0]))
    aug.extend_for_new_codes()
    assert aug["mm"].cardinality == 4
    # minmax rescaled against the new max
    np.testing.assert_allclose(aug["mm"].table[:, 0],
                               (np.array([1, 2, 4, 8.0]) - 1) / 7.0, rtol=1e-6)


def test_interest_stats():
    d, _ = Dictionary.from_data(np.array([1, 1, 1, 1, 50]))
    aug = AugmentedDictionary(d)
    adv = aug.add("f", "float")
    s = adv.interest_stats(d.counts)
    assert 0.0 < s["entropy"] < 1.0
    assert s["peculiarity"] > 1.0


# -- FeatureSet / FeaturePipeline -------------------------------------------------
def _toy_table(n=256, seed=0):
    rng = np.random.default_rng(seed)
    return Table.from_data({
        "age": rng.integers(18, 80, size=n),
        "state": np.array(["CA", "OR", "WA", "NY"])[rng.integers(0, 4, n)],
        "income": rng.integers(20, 200, size=n) * 1000,
    })


def test_pipeline_end_to_end():
    t = _toy_table()
    fs = (FeatureSet()
          .add("age", "zscore")
          .add("age", "bucketize", boundaries=(30.0, 50.0, 65.0))
          .add("state", "onehot")
          .add("income", "minmax"))
    pipe = FeaturePipeline(t, fs)
    assert pipe.out_dim == 1 + 1 + 4 + 1
    idx = np.arange(32)
    dev = np.asarray(pipe.batch(idx))
    host = pipe.batch_recompute(idx)
    np.testing.assert_allclose(dev, host, rtol=1e-5, atol=1e-6)


def test_pipeline_data_movement_win():
    t = _toy_table(n=4096)
    fs = FeatureSet().add("state", "onehot").add("age", "zscore")
    pipe = FeaturePipeline(t, fs)
    packed = FeaturePipeline(t, fs, packed=True)
    b = 1024
    # accounting reports each layout's REAL transfer: 4B int32 codes vs
    # device-width packed words vs row-space f32 features
    assert pipe.bytes_moved_adv(b) < pipe.bytes_moved_recompute(b)
    assert packed.bytes_moved_adv(b) < pipe.bytes_moved_adv(b)
    # state: 2-bit device words vs 4 one-hot floats = 64x; age: 8-bit vs 4B
    assert pipe.bytes_moved_recompute(b) / packed.bytes_moved_adv(b) > 10
    # packed path ships >= 4x fewer bytes than the int32 code matrix
    assert pipe.bytes_moved_adv(b) / packed.bytes_moved_adv(b) >= 4


def test_pipeline_batches_iterator():
    t = _toy_table(n=128)
    pipe = FeaturePipeline(t, FeatureSet().add("age", "float"))
    seen = 0
    for idx, feats in pipe.batches(32, epochs=1):
        assert feats.shape == (32, 1)
        seen += 1
    assert seen == 4


# -- feedback loop (paper §7) ------------------------------------------------------
def test_learned_bucketization_writeback():
    d, codes = _age_dict(n=4000)
    aug = AugmentedDictionary(d)
    scores = d.values.astype(np.float64)          # proxy learned score
    feedback.learn_bucketization(aug, "ml_g1", scores, n_buckets=5,
                                 analysis="run-42")
    assert "ml_g1" in aug
    assert aug["ml_g1"].learned
    b = aug.featurize("ml_g1", codes)[:, 0]
    # count-weighted quantile buckets are roughly balanced
    _, counts = np.unique(b, return_counts=True)
    assert counts.min() > 0.1 * 4000
    # monotone in score
    order = np.argsort(scores)
    assert (np.diff(aug["ml_g1"].table[order, 0]) >= 0).all()


def test_embedding_writeback_and_rank():
    d, _ = _age_dict()
    aug = AugmentedDictionary(d)
    emb = np.random.default_rng(0).standard_normal((d.cardinality, 8))
    feedback.store_embedding(aug, "emb.v1", emb, analysis="pretrain-1")
    assert aug["emb.v1"].dim == 8
    ranks = feedback.rank_features({"a": np.ones(4), "b": np.zeros(4)})
    assert ranks[0][0] == "a"
