"""Property tests for the RLE codec (``columnar/rle.py``).

Round-trips ``rle_encode``/``rle_decode``/``rle_decode_jnp`` across bit
widths 1..16 with controlled run structure (codes built as
``np.repeat(values, lengths)``), plus the degenerate shapes the codec must
survive: empty input, a single run, and unaligned tails interacting with
``pack_bits``. Also pins the ``rle_nbytes`` formula to the run-length
dtype's real width.
"""
from __future__ import annotations

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.columnar.bitpack import pack_bits, unpack_bits
from repro.columnar.rle import rle_decode, rle_decode_jnp, rle_encode, rle_nbytes


def _runs(bits: int, max_runs: int = 12, max_len: int = 9):
    """Strategy producing (values, lengths) lists with the given bit width."""
    return st.lists(
        st.lists(st.integers(0, (1 << bits) - 1), min_size=2, max_size=2).map(
            lambda vl: (vl[0], 1 + vl[1] % max_len)
        ),
        min_size=0,
        max_size=max_runs,
    )


def _codes_from_runs(runs) -> np.ndarray:
    if not runs:
        return np.zeros(0, dtype=np.int32)
    vals = np.asarray([v for v, _ in runs], dtype=np.int32)
    lens = np.asarray([l for _, l in runs], dtype=np.int64)
    return np.repeat(vals, lens).astype(np.int32)


@settings(max_examples=40)
@given(st.integers(1, 16).map(lambda b: b))
def test_roundtrip_all_bit_widths(bits):
    rng = np.random.default_rng(bits)
    lens = rng.integers(1, 7, size=rng.integers(0, 20))
    vals = rng.integers(0, 1 << bits, size=lens.size)
    codes = np.repeat(vals, lens).astype(np.int32)
    values, lengths = rle_encode(codes)
    out = rle_decode(values, lengths)
    np.testing.assert_array_equal(out, codes)
    assert out.dtype == np.int32


@settings(max_examples=30)
@given(_runs(bits=8))
def test_roundtrip_structured_runs(runs):
    codes = _codes_from_runs(runs)
    values, lengths = rle_encode(codes)
    np.testing.assert_array_equal(rle_decode(values, lengths), codes)
    # Total decoded length always matches the input.
    assert int(lengths.sum()) == codes.size


@settings(max_examples=30)
@given(_runs(bits=4))
def test_adjacent_encoded_values_differ(runs):
    codes = _codes_from_runs(runs)
    values, _ = rle_encode(codes)
    if values.size > 1:
        assert np.all(values[1:] != values[:-1])


@settings(max_examples=20)
@given(_runs(bits=6, max_runs=8))
def test_device_decode_matches_host(runs):
    codes = _codes_from_runs(runs)
    values, lengths = rle_encode(codes)
    if values.size == 0:
        return  # searchsorted clip needs >= 1 run; empty is host-only
    dev = np.asarray(rle_decode_jnp(values, lengths, codes.size))
    np.testing.assert_array_equal(dev, codes)


def test_empty_input():
    values, lengths = rle_encode(np.zeros(0, dtype=np.int32))
    assert values.size == 0 and lengths.size == 0
    assert lengths.dtype == np.int64
    assert rle_decode(values, lengths).size == 0
    assert rle_nbytes(values, lengths, 16) == 0


def test_single_run():
    codes = np.full(1000, 7, dtype=np.int32)
    values, lengths = rle_encode(codes)
    assert values.tolist() == [7]
    assert lengths.tolist() == [1000]
    np.testing.assert_array_equal(rle_decode(values, lengths), codes)
    np.testing.assert_array_equal(
        np.asarray(rle_decode_jnp(values, lengths, 1000)), codes
    )


@settings(max_examples=20)
@given(st.integers(1, 16), st.integers(1, 97))
def test_unaligned_tail_pack_interop(bits, n):
    # n deliberately not a multiple of 32: the packed words carry a ragged
    # tail, and RLE must round-trip through pack/unpack bit-exactly.
    rng = np.random.default_rng(bits * 131 + n)
    codes = np.repeat(
        rng.integers(0, 1 << bits, size=(n + 2) // 3), 3
    )[:n].astype(np.int32)
    assert codes.size == n
    values, lengths = rle_encode(codes)
    decoded = rle_decode(values, lengths)
    np.testing.assert_array_equal(decoded, codes)
    words = pack_bits(decoded, bits)
    np.testing.assert_array_equal(unpack_bits(words, bits, n), codes)


@settings(max_examples=30)
@given(_runs(bits=12), st.integers(1, 16))
def test_nbytes_honest_dtype_width(runs, bits):
    codes = _codes_from_runs(runs)
    values, lengths = rle_encode(codes)
    n_runs = values.size
    expect = (n_runs * bits + 7) // 8 + lengths.dtype.itemsize * n_runs
    assert rle_nbytes(values, lengths, bits) == expect
    # int64 lengths must be charged 8 bytes per run.
    assert lengths.dtype.itemsize == 8


def test_rejects_2d_input():
    import pytest

    with pytest.raises(ValueError):
        rle_encode(np.zeros((2, 2), dtype=np.int32))
