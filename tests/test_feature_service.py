"""FeaturePlan/FeatureExecutor/FeatureService: the async ADV serving layer."""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.columnar import Table
from repro.core import FeatureSet, FeaturePipeline, FeaturePlan, FeatureExecutor
from repro.kernels.adv_gather import (fuse_tables, adv_gather_fused,
                                      autotune_fused, fused_kernel_fits,
                                      packed_kernel_fits, ops as adv_ops)
from repro.kernels.adv_gather.ref import adv_gather_multi_ref
from repro.serve import FeatureService


def _toy_table(n=2048, seed=0, imcu_rows=None):
    rng = np.random.default_rng(seed)
    kw = {} if imcu_rows is None else {"imcu_rows": imcu_rows}
    return Table.from_data({
        "age": rng.integers(18, 80, size=n),
        "state": np.array(["CA", "OR", "WA", "NY"])[rng.integers(0, 4, n)],
        "income": rng.integers(20, 200, size=n) * 1000,
    }, **kw)


def _toy_features():
    return (FeatureSet()
            .add("age", "zscore")
            .add("age", "bucketize", boundaries=(30.0, 50.0, 65.0))
            .add("state", "onehot")
            .add("income", "minmax"))


# -- plan/executor ----------------------------------------------------------------
def test_plan_executor_matches_recompute():
    pipe = FeaturePipeline(_toy_table(), _toy_features())
    idx = np.arange(64)
    np.testing.assert_allclose(np.asarray(pipe.batch(idx)),
                               pipe.batch_recompute(idx),
                               rtol=1e-5, atol=1e-6)


def test_executor_kernel_path_matches_take():
    t = _toy_table()
    plan = FeaturePlan(t, _toy_features())
    ex_take = FeatureExecutor(plan, use_kernel=False)
    ex_kern = FeatureExecutor(plan, use_kernel=True)
    idx = np.random.default_rng(1).integers(0, t.n_rows, 500)
    np.testing.assert_allclose(np.asarray(ex_kern.batch(idx)),
                               np.asarray(ex_take.batch(idx)), atol=1e-6)


def test_executor_prefetch_iterator_equivalent():
    """Double-buffered iterator yields the same (idx, features) stream."""
    t = _toy_table(n=640)
    fs = _toy_features()
    deep = FeaturePipeline(t, fs)
    for prefetch in (2, 4):
        ex = FeatureExecutor(FeaturePlan(t, fs), prefetch=prefetch)
        got = list(ex.batches(128, seed=3))
        assert len(got) == 5
        for idx, feats in got:
            np.testing.assert_allclose(np.asarray(feats),
                                       np.asarray(deep.batch(idx)),
                                       atol=1e-6)


def test_executor_rejects_bad_prefetch():
    plan = FeaturePlan(_toy_table(n=64), _toy_features())
    with pytest.raises(ValueError):
        FeatureExecutor(plan, prefetch=0)


# -- fused multi-table kernel ------------------------------------------------------
@pytest.mark.parametrize("cards,dims,n", [
    ((4, 50), (1, 3), 7),
    ((513, 17, 100), (17, 2, 5), 256),
    ((2048, 10), (128, 2), 1000),
    ((1,), (1,), 1),
])
def test_fused_gather_concat_matches_reference(cards, dims, n):
    rng = np.random.default_rng(sum(cards) + n)
    tables = [rng.standard_normal((k, f)).astype(np.float32)
              for k, f in zip(cards, dims)]
    codes = np.stack([rng.integers(0, k, n).astype(np.int32) for k in cards])
    fused = fuse_tables(tables)
    got = np.asarray(adv_gather_fused(fused, jnp.asarray(codes)))
    want = np.asarray(adv_gather_multi_ref(
        jnp.asarray(codes), [jnp.asarray(t) for t in tables]))
    assert got.shape == (n, sum(dims))
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-6)


@given(st.integers(0, 2**31), st.integers(1, 4), st.integers(1, 400))
@settings(max_examples=15, deadline=None)
def test_fused_gather_property(seed, c, n):
    rng = np.random.default_rng(seed)
    cards = [int(rng.integers(1, 300)) for _ in range(c)]
    dims = [int(rng.integers(1, 9)) for _ in range(c)]
    tables = [rng.standard_normal((k, f)).astype(np.float32)
              for k, f in zip(cards, dims)]
    codes = np.stack([rng.integers(0, k, n).astype(np.int32) for k in cards])
    got = np.asarray(adv_gather_fused(fuse_tables(tables), jnp.asarray(codes)))
    want = np.asarray(adv_gather_multi_ref(
        jnp.asarray(codes), [jnp.asarray(t) for t in tables]))
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-6)


def test_autotune_fused_sweeps_and_caches():
    """The int32 fused kernel's (bn, bk) sweep — ported from the packed
    path — returns a valid tiling and caches per workload shape."""
    rng = np.random.default_rng(0)
    tables = [rng.standard_normal((64, 2)).astype(np.float32),
              rng.standard_normal((100, 3)).astype(np.float32)]
    fused = fuse_tables(tables)
    codes = jnp.asarray(np.stack([rng.integers(0, 64, 128),
                                  rng.integers(0, 100, 128)]).astype(np.int32))
    bn, bk = autotune_fused(codes, fused, 128, repeats=1)
    assert fused.table.shape[0] % bk == 0
    # cached: second call returns the same winner without re-sweeping
    assert autotune_fused(codes, fused, 128) == (bn, bk)


def test_executor_autotuned_int32_kernel_matches_take():
    t = _toy_table()
    plan = FeaturePlan(t, _toy_features())
    ex_take = FeatureExecutor(plan, use_kernel=False)
    ex_tune = FeatureExecutor(plan, use_kernel=True, autotune=True)
    idx = np.random.default_rng(5).integers(0, t.n_rows, 128)
    np.testing.assert_allclose(np.asarray(ex_tune.batch(idx)),
                               np.asarray(ex_take.batch(idx)), atol=1e-6)
    assert 128 in ex_tune._fused_blocks_cache      # swept once per shape


def test_int32_kernel_respects_vmem_budget(monkeypatch):
    """The ~16MB ΣK×ΣF guard — ported from the packed path — now gates the
    int32 fused kernel too: past budget the executor splits into takes."""
    assert fused_kernel_fits((100, 50), (4, 4))
    assert not fused_kernel_fits((1 << 15, 1 << 15), (64, 64))  # ~16MB guard
    assert packed_kernel_fits is fused_kernel_fits              # one guard
    plan = FeaturePlan(_toy_table(), _toy_features())
    ex = FeatureExecutor(plan, use_kernel=True)
    assert ex.kernel_active
    monkeypatch.setattr(adv_ops, "fused_kernel_fits",
                        lambda *a, **k: False)
    assert not ex.kernel_active                    # guard consulted live
    idx = np.arange(64)
    np.testing.assert_allclose(                    # split path still serves
        np.asarray(ex.batch(idx)),
        np.asarray(FeatureExecutor(plan).batch(idx)), atol=1e-6)


def test_fused_tables_reports_cost():
    fused = fuse_tables([np.ones((100, 2), np.float32),
                         np.ones((50, 3), np.float32)])
    assert fused.out_dim == 5
    assert fused.cards == (100, 50)
    assert fused.nbytes >= 150 * 5 * 4        # block-diagonal layout price


# -- FeatureService ---------------------------------------------------------------
@pytest.mark.parametrize("use_kernel", [False, True])
def test_service_matches_direct_batch(use_kernel):
    pipe = FeaturePipeline(_toy_table(), _toy_features())
    svc = FeatureService(pipe, use_kernel=use_kernel)
    rng = np.random.default_rng(2)
    rows = [rng.integers(0, 2048, sz) for sz in (3, 64, 200, 1024)]
    tickets = [svc.submit(r) for r in rows]
    for r, tk in zip(rows, tickets):
        np.testing.assert_allclose(svc.result(tk), np.asarray(pipe.batch(r)),
                                   atol=1e-6)


def test_service_double_buffer_depth_and_bucketing():
    pipe = FeaturePipeline(_toy_table(), _toy_features())
    svc = FeatureService(pipe, prefetch=3, buckets=(32, 128))
    rng = np.random.default_rng(3)
    tickets = [svc.submit(rng.integers(0, 2048, 20)) for _ in range(8)]
    # oversized request splits into max-bucket chunks
    big = rng.integers(0, 2048, 300)
    tk = svc.submit(big)
    np.testing.assert_allclose(svc.result(tk), np.asarray(pipe.batch(big)),
                               atol=1e-6)
    out = svc.drain()
    assert set(out) == set(tickets)
    assert svc.stats["max_inflight"] <= 3          # window respected
    assert svc.stats["max_inflight"] >= 2          # actually double-buffered
    assert svc.stats["padded_rows"] > 0            # 20 -> bucket 32


def test_service_sharded_routing():
    """Per-IMCU shard plans: routed slices equal the unsharded path."""
    t = _toy_table(n=3000, imcu_rows=700)          # 5 partitions
    pipe = FeaturePipeline(t, _toy_features())
    assert t["age"].n_imcus == 5
    svc = FeatureService(pipe.plan, sharded=True)
    rng = np.random.default_rng(4)
    rows = rng.integers(0, 3000, 900)              # crosses all partitions
    np.testing.assert_allclose(svc.result(svc.submit(rows)),
                               np.asarray(pipe.batch(rows)), atol=1e-6)


def test_service_serve_stream_order():
    pipe = FeaturePipeline(_toy_table(n=512), _toy_features())
    svc = FeatureService(pipe)
    rng = np.random.default_rng(5)
    batches = [rng.integers(0, 512, 64) for _ in range(6)]
    got = list(svc.serve_stream(iter(batches)))
    assert len(got) == 6
    for (rows, feats), want_rows in zip(got, batches):
        np.testing.assert_array_equal(rows, want_rows)
        np.testing.assert_allclose(feats, np.asarray(pipe.batch(want_rows)),
                                   atol=1e-6)


def test_service_poll_completes_without_result_call():
    """poll() retires finished device work itself — a single request below
    the prefetch depth must still become ready (no livelock)."""
    import time
    pipe = FeaturePipeline(_toy_table(n=256), _toy_features())
    svc = FeatureService(pipe)
    tk = svc.submit(np.arange(32))
    deadline = time.perf_counter() + 30.0
    while not svc.poll(tk):
        assert time.perf_counter() < deadline, "poll never became ready"
        time.sleep(0.001)
    np.testing.assert_allclose(svc.result(tk),
                               np.asarray(pipe.batch(np.arange(32))),
                               atol=1e-6)


def test_service_bad_ticket_fails_fast():
    pipe = FeaturePipeline(_toy_table(n=256), _toy_features())
    svc = FeatureService(pipe)
    tk = svc.submit(np.arange(16))
    with pytest.raises(KeyError):              # bad ticket errors, and the
        svc.result(9999)                       # pending one still completes
    with pytest.raises(KeyError):              # poll agrees with result
        svc.poll(9999)
    assert svc.result(tk).shape == (16, pipe.out_dim)
    with pytest.raises(KeyError):              # collected tickets don't spin
        svc.poll(tk)


def test_service_window_bounds_chunks_of_one_request():
    """An oversized request's chunks count against the prefetch window
    individually — device output buffers can't pile up unbounded."""
    pipe = FeaturePipeline(_toy_table(n=2048), _toy_features())
    svc = FeatureService(pipe, prefetch=2, buckets=(64,))
    rows = np.random.default_rng(0).integers(0, 2048, 64 * 20)   # 20 chunks
    tk = svc.submit(rows)
    np.testing.assert_allclose(svc.result(tk), np.asarray(pipe.batch(rows)),
                               atol=1e-6)
    assert svc.stats["batches"] == 20
    assert svc.stats["max_inflight"] <= 2


def test_service_rejects_bad_requests():
    svc = FeatureService(FeaturePlan(_toy_table(n=100), _toy_features()))
    with pytest.raises(ValueError):
        svc.submit(np.array([], dtype=np.int64))
    with pytest.raises(IndexError):
        svc.submit(np.array([100]))
    with pytest.raises(ValueError):
        FeatureService(FeaturePlan(_toy_table(n=100), _toy_features()),
                       prefetch=1)


# -- incremental plan refresh -------------------------------------------------------
def test_plan_refresh_incremental_after_insert():
    t = _toy_table(n=400)
    pipe = FeaturePipeline(t, _toy_features())
    plan = pipe.plan
    put_before = plan.stats["tables_put"]
    # grow only the age dictionary (new max value -> minmax/zscore rescale);
    # state/income inserts reuse existing values so their plans must not move
    age_codes = t["age"].dictionary.add_rows(np.array([150, 151]))
    state_codes = t["state"].dictionary.add_rows(
        t["state"].dictionary.values[:2])
    income_codes = t["income"].dictionary.add_rows(
        t["income"].dictionary.values[:2])
    refreshed = plan.refresh({"age": age_codes, "state": state_codes,
                              "income": income_codes})
    assert refreshed == 1                           # only 'age' changed
    assert plan.stats["tables_refreshed"] == 1
    assert plan.stats["tables_put"] == put_before   # no extra device puts
    assert plan.n_rows == 402
    new_rows = np.array([400, 401])
    np.testing.assert_allclose(np.asarray(pipe.batch(new_rows)),
                               pipe.batch_recompute(new_rows),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_refresh_invalidates_compiled_batch_shapes(use_kernel):
    """A batch shape compiled BEFORE a refresh must serve the new tables
    afterwards (tables are jit arguments, not trace-time constants)."""
    t = _toy_table(n=300)
    pipe = FeaturePipeline(t, _toy_features(), use_kernel=use_kernel)
    idx = np.arange(64)
    np.asarray(pipe.batch(idx))                     # compile the (C, 64) shape
    # grow the age dictionary: zscore/minmax/bucketize tables all rescale
    t["age"].dictionary.add_rows(np.array([150]))
    pipe.plan.refresh({"age": np.array([0], np.int32),
                       "state": np.array([0], np.int32),
                       "income": np.array([0], np.int32)})
    np.testing.assert_allclose(np.asarray(pipe.batch(idx)),
                               pipe.batch_recompute(idx),
                               rtol=1e-5, atol=1e-6)


def test_kernel_falls_back_for_huge_cardinality():
    """use_kernel honors the single-table op's K guard: huge-K plans use the
    XLA gather instead of materializing a giant one-hot super-table."""
    rng = np.random.default_rng(0)
    t = Table.from_data({"zip": rng.integers(0, 1 << 17, 200_000)})
    pipe = FeaturePipeline(t, FeatureSet().add("zip", "zscore"),
                           use_kernel=True)
    assert not pipe.executor.kernel_active
    assert pipe.plan._fused_box["t"] is None        # never built
    idx = rng.integers(0, 200_000, 100)
    np.testing.assert_allclose(np.asarray(pipe.batch(idx)),
                               pipe.batch_recompute(idx),
                               rtol=1e-5, atol=1e-6)


def test_sharded_service_serves_rows_appended_after_refresh():
    t = _toy_table(n=2000, imcu_rows=800)
    pipe = FeaturePipeline(t, _toy_features())
    svc = FeatureService(pipe.plan, sharded=True)
    svc.result(svc.submit(np.arange(64)))           # compile bucket pre-refresh
    new = {"age": t["age"].dictionary.add_rows(np.array([150, 151])),
           "state": t["state"].dictionary.add_rows(np.array(["CA", "OR"])),
           "income": t["income"].dictionary.add_rows(np.array([40000,
                                                               60000]))}
    pipe.plan.refresh(new)
    mixed = np.array([0, 799, 800, 1999, 2000, 2001])   # spans shards + tail
    np.testing.assert_allclose(svc.result(svc.submit(mixed)),
                               pipe.batch_recompute(mixed),
                               rtol=1e-5, atol=1e-6)


def test_plan_refresh_requires_aligned_codes():
    plan = FeaturePlan(_toy_table(n=100), _toy_features())
    with pytest.raises(KeyError):
        plan.refresh({"age": np.array([0])})


def test_plan_refresh_count_only_insert_rescales_zscore():
    """Duplicate-value inserts leave cardinality unchanged but shift the
    count-weighted mean/std — count-sensitive ADVs must still rebuild."""
    rng = np.random.default_rng(0)
    t = Table.from_data({"age": rng.integers(18, 80, 400)})
    pipe = FeaturePipeline(t, FeatureSet().add("age", "zscore"))
    idx = np.arange(64)
    np.asarray(pipe.batch(idx))                     # compile pre-refresh
    existing = t["age"].dictionary.values[0]
    codes = t["age"].dictionary.add_rows(np.full(200, existing))
    assert pipe.plan.refresh({"age": codes}) == 1   # version moved, K did not
    np.testing.assert_allclose(np.asarray(pipe.batch(idx)),
                               pipe.batch_recompute(idx),
                               rtol=1e-5, atol=1e-6)


def test_fused_gather_clamps_out_of_range_codes():
    """OOB codes must clamp inside their own table's block (take semantics),
    not silently gather rows from the next table."""
    rng = np.random.default_rng(1)
    tables = [rng.standard_normal((10, 2)).astype(np.float32),
              rng.standard_normal((20, 3)).astype(np.float32)]
    codes = np.array([[0, 9, 15, -2],               # 15 and -2 out of range
                      [19, 0, 25, 1]], np.int32)
    got = np.asarray(adv_gather_fused(fuse_tables(tables),
                                      jnp.asarray(codes)))
    want = np.asarray(adv_gather_multi_ref(                # jnp.take clamps
        jnp.asarray(codes), [jnp.asarray(t) for t in tables]))
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-6)


def test_plan_refresh_bad_codes_leaves_plan_untouched():
    plan = FeaturePlan(_toy_table(n=100), _toy_features())
    n_before = plan.n_rows
    with pytest.raises(KeyError):
        plan.refresh({"age": np.array([0], np.int32)})     # missing columns
    assert plan.n_rows == n_before


def test_plan_refresh_noop_when_nothing_changed():
    plan = FeaturePlan(_toy_table(n=100), _toy_features())
    assert plan.refresh() == 0
    assert plan.stats["tables_refreshed"] == 0


def test_shard_fused_tables_shared_and_refresh_invalidates_all_views():
    t = _toy_table(n=1600, imcu_rows=800)
    plan = FeaturePlan(t, _toy_features())
    shards = plan.imcu_shards()
    f0 = shards[0].fused_tables()
    assert shards[1].fused_tables() is f0          # shared, not re-put
    assert plan.fused_tables() is f0
    assert plan.stats["fused_rebuilds"] == 1
    t["age"].dictionary.add_rows(np.array([150]))
    assert plan.refresh() >= 1
    f1 = shards[1].fused_tables()                  # rebuilt for EVERY view
    assert f1 is not f0
    assert shards[0].fused_tables() is f1 and plan.fused_tables() is f1
