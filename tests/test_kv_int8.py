"""int8 dictionary-quantized KV cache: serve path stays faithful."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import lm
from repro.models.blocks import _kv_quantize, _kv_dequantize


def test_kv_quantize_roundtrip():
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.standard_normal((2, 8, 4, 16)) * 3, jnp.float32)
    q, s = _kv_quantize(k)
    assert q.dtype == jnp.int8 and s.shape == (2, 8, 4)
    back = _kv_dequantize(q, s, jnp.float32)
    err = np.abs(np.asarray(k - back))
    bound = np.asarray(s)[..., None] * 0.51 + 1e-6
    assert (err <= bound).all()


@pytest.mark.parametrize("arch", ["glm4-9b", "qwen2-7b"])
def test_int8_cache_decode_close_to_bf16(arch):
    cfg = dataclasses.replace(reduced(get_config(arch)), dtype="float32")
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    S, B = 8, 2
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    def run(c):
        state = lm.init_serve_state(c, B, max_len=S)
        logits, state = lm.prefill(c, params, state,
                                   {"tokens": tokens[:, :S - 1]})
        step, state = lm.decode_step(c, params, state, tokens[:, S - 1:])
        return np.asarray(logits), np.asarray(step)

    pre_f, step_f = run(cfg)
    pre_q, step_q = run(cfg8)
    # quantized cache tracks full-precision logits closely (not exactly)
    np.testing.assert_allclose(pre_q, pre_f, rtol=0.1, atol=0.15)
    np.testing.assert_allclose(step_q, step_f, rtol=0.1, atol=0.15)
    # and the argmax decisions agree almost everywhere
    agree = (pre_q.argmax(-1) == pre_f.argmax(-1)).mean()
    assert agree > 0.9, agree


def test_int8_cache_memory_halves():
    cfg = reduced(get_config("glm4-9b"))
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    s16 = lm.init_serve_state(cfg, 2, max_len=64)
    s8 = lm.init_serve_state(cfg8, 2, max_len=64)
    def nbytes(t):
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(t))
    assert nbytes(s8) < 0.62 * nbytes(s16)
